//! O(1)-memory streaming quantile sketch (Greenwald–Khanna style).
//!
//! The exact [`super::Digest`] stores every sample, so metric memory grows
//! linearly in trace length — fatal for million-request sweeps (ROADMAP
//! Open item 4). [`GkSketch`] keeps a small sorted summary of tuples
//! `(v, g, Δ)` maintaining the GK invariant `g_i + Δ_i ≤ ⌊2εn⌋`, which
//! guarantees every quantile query is answered by a stored value whose
//! *rank* is within `±εn` of the requested one (proof sketch below; the
//! property test in this file checks the bound empirically on four
//! adversarial distributions against the exact digest).
//!
//! Determinism: the sketch is a pure fold over the sample stream — no
//! RNG, no wall clock, no hashing (pallas-lint `det-entropy` /
//! `det-collections` clean). Identical streams produce bit-identical
//! summaries and query answers.
//!
//! Rank-error argument (query): for each stored tuple let
//! `rmin_i = Σ_{j≤i} g_j` and `rmax_i = rmin_i + Δ_i` bound the true rank
//! of `v_i`. The query walks tuples until
//! `rmin_i + g_{i+1} + Δ_{i+1} > desired + εn` and returns `v_i`:
//! not stopping at `i-1` gives `rmax_i ≤ desired + εn`, and the stop
//! condition plus the invariant `g_{i+1} + Δ_{i+1} ≤ 2εn` gives
//! `rmin_i ≥ desired − εn`, so the true rank of the answer lies in
//! `desired ± εn`.
//!
//! Space: this is the classic band-less compressor — worst-case size
//! `O((1/ε)·log(εn))` is proven only for the banded variant, so we do
//! not claim a closed-form bound here; instead the tests assert the
//! summary stays orders of magnitude under the sample count and grows
//! sublinearly (see `entries_grow_sublinearly`), and the huge-sweep CI
//! smoke asserts trace-length independence end-to-end (DESIGN.md §6).

/// Default rank-error budget: quantiles within ±0.1% of the true rank —
/// tight enough that p99 on a 10⁶-request cell is off by ≤ ~1000 ranks
/// either side of rank 990 000, far inside seed-to-seed noise.
pub const DEFAULT_EPSILON: f64 = 1e-3;

/// One GK summary entry: a stored sample `v`, the gap `g` between the
/// minimum ranks of this and the previous entry, and the rank
/// uncertainty `delta` (`rmax - rmin`) of this entry.
#[derive(Debug, Clone, Copy)]
struct Tuple {
    v: f64,
    g: u64,
    delta: u64,
}

/// Deterministic streaming quantile sketch with ±εn rank-error quantiles
/// and exact running count / sum / min / max.
///
/// Memory is independent of how many samples flow through (see module
/// docs for the honest statement of the space bound). Used as the
/// [`super::MetricsMode::Streaming`] backend of [`super::TailDigest`].
#[derive(Debug, Clone)]
pub struct GkSketch {
    eps: f64,
    /// Sorted by `v` (ties keep insertion-point order — deterministic).
    tuples: Vec<Tuple>,
    n: u64,
    /// Inserts since the last compression pass.
    since_compress: u64,
    /// Compress every this-many inserts (≈ 1/(2ε)).
    period: u64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Default for GkSketch {
    fn default() -> Self {
        Self::with_epsilon(DEFAULT_EPSILON)
    }
}

impl GkSketch {
    /// Sketch with the [`DEFAULT_EPSILON`] rank-error budget.
    pub fn new() -> Self {
        Self::default()
    }

    /// Sketch answering quantiles within `±eps·n` rank error.
    pub fn with_epsilon(eps: f64) -> Self {
        assert!(eps > 0.0 && eps < 0.5, "epsilon out of range: {eps}");
        Self {
            eps,
            tuples: Vec::new(),
            n: 0,
            since_compress: 0,
            period: (1.0 / (2.0 * eps)).floor().max(1.0) as u64,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sum: 0.0,
        }
    }

    /// The configured rank-error budget ε.
    pub fn epsilon(&self) -> f64 {
        self.eps
    }

    /// Observe one sample.
    pub fn add(&mut self, v: f64) {
        debug_assert!(v.is_finite(), "non-finite sample {v}");
        self.n += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        let cap = (2.0 * self.eps * self.n as f64).floor() as u64;
        // Samples are finite (debug-asserted), so plain `<` is a total
        // order here; ties insert after their equals — deterministic.
        let i = self.tuples.partition_point(|t| t.v < v);
        let delta = if i == 0 || i == self.tuples.len() {
            // New minimum / maximum: its rank is known exactly.
            0
        } else {
            cap.saturating_sub(1)
        };
        self.tuples.insert(i, Tuple { v, g: 1, delta });
        self.since_compress += 1;
        if self.since_compress >= self.period {
            self.compress();
            self.since_compress = 0;
        }
    }

    /// Merge adjacent tuples whose combined rank span still fits the
    /// `⌊2εn⌋` invariant. One backward pass; the first tuple is never
    /// merged away so the minimum stays exactly represented.
    fn compress(&mut self) {
        if self.tuples.len() < 3 {
            return;
        }
        let cap = (2.0 * self.eps * self.n as f64).floor() as u64;
        let mut i = self.tuples.len() - 2;
        while i >= 1 {
            let merged_g = self.tuples[i].g + self.tuples[i + 1].g;
            if merged_g + self.tuples[i + 1].delta <= cap {
                self.tuples[i + 1].g = merged_g;
                self.tuples.remove(i);
            }
            i -= 1;
        }
    }

    /// Number of samples observed (exact).
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Stored summary tuples — the memory footprint the huge-sweep smoke
    /// asserts is trace-length independent.
    pub fn entries(&self) -> usize {
        self.tuples.len()
    }

    /// A stored sample whose rank is within `±εn` of `q·n`; `None` when
    /// empty. `q` outside [0, 1] is clamped.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let desired = q * self.n as f64;
        let e = self.eps * self.n as f64;
        let mut rmin: u64 = 0;
        for w in self.tuples.windows(2) {
            rmin += w[0].g;
            if rmin as f64 + (w[1].g + w[1].delta) as f64 > desired + e {
                return Some(w[0].v);
            }
        }
        Some(self.tuples[self.tuples.len() - 1].v)
    }

    /// Exact arithmetic mean (running sum / count); `None` when empty.
    pub fn mean(&self) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        Some(self.sum / self.n as f64)
    }

    /// Exact minimum; `None` when empty.
    pub fn min(&self) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        Some(self.min)
    }

    /// Exact maximum; `None` when empty.
    pub fn max(&self) -> Option<f64> {
        if self.n == 0 {
            return None;
        }
        Some(self.max)
    }

    /// Fold another sketch's summary into this one without touching any
    /// raw samples — the O(1)-memory cross-seed pooling primitive.
    ///
    /// Rank-error argument: a tuple from one summary, placed among the
    /// other's tuples, gains at most the other stream's full rank
    /// uncertainty, so bumping its `Δ` by `⌊2ε·n_other⌋` keeps
    /// `g + Δ ≤ ⌊2ε·n_self⌋ + ⌊2ε·n_other⌋ ≤ ⌊2ε·(n_self+n_other)⌋` —
    /// the GK invariant at the pooled count, hence pooled queries stay
    /// within `±ε·n_total` ranks. The boundary tuples keep `Δ = 0`: each
    /// input's first/last tuple is its exact min/max (inserts at the ends
    /// get `Δ = 0` and compression never discards them), so the merged
    /// first tuple is the exact pooled minimum (rank = its `g`-prefix)
    /// and the merged last tuple the exact pooled maximum (`Σg = n`).
    /// Count, sum, min and max combine exactly. Deterministic: a stable
    /// two-pointer merge by `v`, `self`'s tuples first on ties.
    pub fn merge(&mut self, other: &GkSketch) {
        assert!(
            (self.eps - other.eps).abs() < 1e-12,
            "merging sketches with different epsilon ({} vs {})",
            self.eps,
            other.eps
        );
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let bump_self = (2.0 * self.eps * other.n as f64).floor() as u64;
        let bump_other = (2.0 * self.eps * self.n as f64).floor() as u64;
        let mut merged = Vec::with_capacity(self.tuples.len() + other.tuples.len());
        let (mut i, mut j) = (0, 0);
        while i < self.tuples.len() || j < other.tuples.len() {
            let take_self = j >= other.tuples.len()
                || (i < self.tuples.len() && self.tuples[i].v <= other.tuples[j].v);
            let mut t = if take_self {
                i += 1;
                self.tuples[i - 1]
            } else {
                j += 1;
                other.tuples[j - 1]
            };
            t.delta += if take_self { bump_self } else { bump_other };
            merged.push(t);
        }
        if let Some(first) = merged.first_mut() {
            first.delta = 0;
        }
        if let Some(last) = merged.last_mut() {
            last.delta = 0;
        }
        self.tuples = merged;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.compress();
        self.since_compress = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const N: usize = 50_000;
    const QS: [f64; 7] = [0.01, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999];

    /// True-rank error of the sketch's answer for quantile `q`, in ranks:
    /// how far `q·n` falls outside the closed rank interval the returned
    /// value occupies in the exact sorted sample set.
    fn rank_err(sorted: &[f64], answer: f64, q: f64) -> f64 {
        let lo = sorted.partition_point(|&x| x < answer) as f64;
        let hi = sorted.partition_point(|&x| x <= answer) as f64;
        let desired = q * sorted.len() as f64;
        if desired < lo {
            lo - desired
        } else if desired > hi {
            desired - hi
        } else {
            0.0
        }
    }

    fn check_distribution(name: &str, samples: Vec<f64>) {
        let mut sk = GkSketch::new();
        for &v in &samples {
            sk.add(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let budget = sk.epsilon() * samples.len() as f64 + 1.0;
        for q in QS {
            let ans = sk.quantile(q).unwrap();
            let err = rank_err(&sorted, ans, q);
            assert!(
                err <= budget,
                "{name}: q={q} rank error {err} > budget {budget} (answer {ans})"
            );
        }
        // Exact side-channels stay exact regardless of distribution.
        assert_eq!(sk.count() as usize, samples.len());
        assert_eq!(sk.min(), Some(sorted[0]));
        assert_eq!(sk.max(), Some(sorted[sorted.len() - 1]));
        let naive_mean = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!((sk.mean().unwrap() - naive_mean).abs() < 1e-6 * naive_mean.abs().max(1.0));
        // The memory claim: summary orders of magnitude under the stream.
        assert!(
            sk.entries() < samples.len() / 10,
            "{name}: {} entries for {} samples",
            sk.entries(),
            samples.len()
        );
    }

    #[test]
    fn rank_error_bounded_on_uniform() {
        let mut rng = Rng::seed_from_u64(0x6b_01);
        check_distribution("uniform", (0..N).map(|_| rng.f64() * 100.0).collect());
    }

    #[test]
    fn rank_error_bounded_on_pareto_heavy_tail() {
        // Pareto(xm=1, alpha=1.1) via inverse transform — infinite
        // variance, the adversarial tail for naive bucketing sketches.
        let mut rng = Rng::seed_from_u64(0x6b_02);
        let samples = (0..N)
            .map(|_| (1.0 - rng.f64()).powf(-1.0 / 1.1))
            .collect();
        check_distribution("pareto", samples);
    }

    #[test]
    fn rank_error_bounded_on_constant() {
        check_distribution("constant", vec![42.0; N]);
    }

    #[test]
    fn rank_error_bounded_on_sorted() {
        // Monotone stream: every insert lands at the end (the max-
        // boundary special case) and compression does all the work.
        check_distribution("sorted", (0..N).map(|i| i as f64).collect());
    }

    #[test]
    fn entries_grow_sublinearly() {
        let sizes = [20_000usize, 80_000];
        let mut entry_counts = Vec::new();
        for &n in &sizes {
            let mut rng = Rng::seed_from_u64(0x6b_03);
            let mut sk = GkSketch::new();
            for _ in 0..n {
                sk.add(rng.f64());
            }
            entry_counts.push(sk.entries());
        }
        // 4x the data must cost well under 4x the summary.
        assert!(
            (entry_counts[1] as f64) < 2.0 * entry_counts[0] as f64,
            "entries {entry_counts:?} for sizes {sizes:?}"
        );
    }

    #[test]
    fn empty_and_single() {
        let mut sk = GkSketch::new();
        assert_eq!(sk.quantile(0.5), None);
        assert_eq!(sk.mean(), None);
        assert_eq!(sk.max(), None);
        assert_eq!(sk.count(), 0);
        sk.add(3.5);
        assert_eq!(sk.quantile(0.0), Some(3.5));
        assert_eq!(sk.quantile(1.0), Some(3.5));
        assert_eq!(sk.mean(), Some(3.5));
    }

    #[test]
    fn deterministic_across_identical_streams() {
        let run = || {
            let mut rng = Rng::seed_from_u64(0x6b_04);
            let mut sk = GkSketch::new();
            for _ in 0..10_000 {
                sk.add(rng.exponential(0.1));
            }
            QS.map(|q| sk.quantile(q).unwrap().to_bits())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn merged_sketch_stays_within_pooled_rank_budget() {
        // Pool several per-seed streams by summary merge and check the
        // rank bound against the exact pooled sample set — the
        // aggregate_seeds streaming-mode contract.
        let mut rng = Rng::seed_from_u64(0x6b_06);
        let mut pooled = GkSketch::new();
        let mut all = Vec::new();
        for part in 0..5 {
            let mut sk = GkSketch::new();
            let n = 3_000 + 2_000 * part;
            for _ in 0..n {
                // Disjoint-ish ranges per part make a bad merge obvious.
                let v = rng.exponential(0.1) + 10.0 * part as f64;
                sk.add(v);
                all.push(v);
            }
            pooled.merge(&sk);
        }
        let mut sorted = all.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let budget = pooled.epsilon() * all.len() as f64 + 1.0;
        for q in QS {
            let ans = pooled.quantile(q).unwrap();
            let err = rank_err(&sorted, ans, q);
            assert!(err <= budget, "q={q}: rank error {err} > {budget}");
        }
        // Exact side-channels combine exactly.
        assert_eq!(pooled.count() as usize, all.len());
        assert_eq!(pooled.min(), Some(sorted[0]));
        assert_eq!(pooled.max(), Some(sorted[sorted.len() - 1]));
        let naive = all.iter().sum::<f64>() / all.len() as f64;
        assert!((pooled.mean().unwrap() - naive).abs() < 1e-6 * naive.abs());
        // Still a summary, not a rehydrated sample store.
        assert!(pooled.entries() < all.len() / 10);
    }

    #[test]
    fn merge_with_empty_is_identity_either_way() {
        let mut a = GkSketch::new();
        for i in 0..1_000 {
            a.add(i as f64);
        }
        let before = (a.count(), a.quantile(0.5).map(f64::to_bits));
        a.merge(&GkSketch::new());
        assert_eq!((a.count(), a.quantile(0.5).map(f64::to_bits)), before);
        let mut e = GkSketch::new();
        e.merge(&a);
        assert_eq!(e.count(), a.count());
        assert_eq!(e.quantile(0.99), a.quantile(0.99));
    }

    #[test]
    fn quantile_answers_are_stored_samples() {
        // GK answers must be actual observed values, never interpolated —
        // that's what makes the rank argument well-defined.
        let mut rng = Rng::seed_from_u64(0x6b_05);
        let samples: Vec<f64> = (0..5_000).map(|_| (rng.f64() * 1e6).floor()).collect();
        let mut sk = GkSketch::new();
        for &v in &samples {
            sk.add(v);
        }
        for q in QS {
            let ans = sk.quantile(q).unwrap();
            assert!(samples.contains(&ans), "q={q}: {ans} not in stream");
        }
    }
}
