//! Measurement machinery: percentile digests, GPU idle accounting (Eq. 1),
//! throughput, JCT, preemption counters, scheduling-overhead timers and an
//! execution-timeline recorder ([`timeline`]).
//!
//! Two percentile backends live here (DESIGN.md §6): the exact [`Digest`]
//! (stores every sample — the equivalence oracle, fine at testbed scale)
//! and the O(1)-memory streaming [`GkSketch`]. [`TailDigest`] switches a
//! run's tail metrics between them via [`MetricsMode`], so million-request
//! sweeps stay flat in trace length.

pub mod sketch;
pub mod timeline;

pub use sketch::GkSketch;
pub use timeline::{Activity, Span, Timeline};


/// The percentile set every delay figure in the paper reports.
pub const PAPER_PERCENTILES: [f64; 5] = [0.01, 0.25, 0.50, 0.75, 0.99];

/// Which percentile backend a run's [`TailDigest`]s use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsMode {
    /// Store every sample ([`Digest`]) — exact quantiles, O(n) memory.
    /// The default, and the oracle the streaming mode is tested against.
    #[default]
    Exact,
    /// Greenwald–Khanna sketch ([`GkSketch`]) — quantiles within a
    /// provable rank error of ±εn, memory independent of trace length.
    Streaming,
}

/// Exact percentile digest (stores samples; fine at testbed trace scale).
///
/// Empty-digest behavior is uniform across the query surface: every
/// query ([`Digest::quantile`], [`Digest::mean`], [`Digest::max`],
/// [`Digest::paper_percentiles`]) returns `None` when no samples were
/// added, never a sentinel and never a panic.
#[derive(Debug, Clone)]
pub struct Digest {
    samples: Vec<f64>,
    sorted: bool,
    /// Running maximum, maintained on [`Digest::add`] so `max` never has
    /// to sort (it used to ensure_sorted — O(n log n) to read one value).
    max_seen: f64,
}

impl Default for Digest {
    fn default() -> Self {
        Self {
            samples: Vec::new(),
            sorted: true,
            max_seen: f64::NEG_INFINITY,
        }
    }
}

impl Digest {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        debug_assert!(v.is_finite(), "non-finite sample {v}");
        self.samples.push(v);
        self.sorted = false;
        if v > self.max_seen {
            self.max_seen = v;
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Linear-interpolated quantile, `q` in [0, 1]; `None` when empty.
    pub fn quantile(&mut self, q: f64) -> Option<f64> {
        assert!((0.0..=1.0).contains(&q));
        if self.samples.is_empty() {
            return None;
        }
        self.ensure_sorted();
        let n = self.samples.len();
        if n == 1 {
            return Some(self.samples[0]);
        }
        let pos = q * (n - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        Some(self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac)
    }

    /// Arithmetic mean; `None` when empty.
    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        Some(self.samples.iter().sum::<f64>() / self.samples.len() as f64)
    }

    /// Largest sample (tracked on `add` — O(1)); `None` when empty.
    pub fn max(&self) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        Some(self.max_seen)
    }

    /// The paper's five percentiles (p1, p25, p50, p75, p99); `None` when
    /// empty.
    pub fn paper_percentiles(&mut self) -> Option<[f64; 5]> {
        if self.samples.is_empty() {
            return None;
        }
        let mut out = [0.0; 5];
        for (i, q) in PAPER_PERCENTILES.iter().enumerate() {
            out[i] = self.quantile(*q)?;
        }
        Some(out)
    }

    /// Pool another exact digest's samples into this one (in the other's
    /// stored order — deterministic for identical inputs).
    pub fn merge(&mut self, other: &Digest) {
        for &v in &other.samples {
            self.add(v);
        }
    }
}

/// A tail-metric digest with a switchable backend: the exact [`Digest`]
/// oracle or the O(1)-memory streaming [`GkSketch`].
///
/// `mean`/`max`/`len` are exact in *both* modes (the sketch tracks running
/// count/sum/max beside its tuples); only `quantile` carries the ±εn rank
/// error in streaming mode. The query surface mirrors [`Digest`]:
/// `None` on empty, never a sentinel.
#[derive(Debug, Clone)]
pub enum TailDigest {
    /// Exact backend — stores every sample.
    Exact(Digest),
    /// Streaming backend — bounded-memory GK sketch.
    Streaming(GkSketch),
}

impl Default for TailDigest {
    fn default() -> Self {
        TailDigest::Exact(Digest::new())
    }
}

impl TailDigest {
    /// Build the backend for `mode` (streaming uses
    /// [`sketch::DEFAULT_EPSILON`]).
    pub fn new(mode: MetricsMode) -> Self {
        match mode {
            MetricsMode::Exact => TailDigest::Exact(Digest::new()),
            MetricsMode::Streaming => TailDigest::Streaming(GkSketch::new()),
        }
    }

    pub fn add(&mut self, v: f64) {
        match self {
            TailDigest::Exact(d) => d.add(v),
            TailDigest::Streaming(s) => s.add(v),
        }
    }

    /// Number of samples observed (exact in both modes).
    pub fn len(&self) -> usize {
        match self {
            TailDigest::Exact(d) => d.len(),
            TailDigest::Streaming(s) => s.count() as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Quantile, `q` in [0, 1]; exact or within ±εn rank error depending
    /// on the backend. `None` when empty.
    pub fn quantile(&mut self, q: f64) -> Option<f64> {
        match self {
            TailDigest::Exact(d) => d.quantile(q),
            TailDigest::Streaming(s) => s.quantile(q),
        }
    }

    /// Arithmetic mean — exact in both modes. `None` when empty.
    pub fn mean(&self) -> Option<f64> {
        match self {
            TailDigest::Exact(d) => d.mean(),
            TailDigest::Streaming(s) => s.mean(),
        }
    }

    /// Largest sample — exact in both modes. `None` when empty.
    pub fn max(&self) -> Option<f64> {
        match self {
            TailDigest::Exact(d) => d.max(),
            TailDigest::Streaming(s) => s.max(),
        }
    }

    /// The paper's five percentiles; `None` when empty.
    pub fn paper_percentiles(&mut self) -> Option<[f64; 5]> {
        match self {
            TailDigest::Exact(d) => d.paper_percentiles(),
            TailDigest::Streaming(s) => {
                if s.count() == 0 {
                    return None;
                }
                let mut out = [0.0; 5];
                for (i, q) in PAPER_PERCENTILES.iter().enumerate() {
                    out[i] = s.quantile(*q)?;
                }
                Some(out)
            }
        }
    }

    /// Stored entries backing this digest: samples (exact) or sketch
    /// tuples (streaming). The memory-flatness gate the huge-sweep smoke
    /// asserts on — streaming entries must not grow with trace length.
    pub fn entries(&self) -> usize {
        match self {
            TailDigest::Exact(d) => d.len(),
            TailDigest::Streaming(s) => s.entries(),
        }
    }

    /// Pool another digest into this one (cross-seed quantile pooling).
    ///
    /// Exact + Exact concatenates sample sets (pooled quantiles stay
    /// exact). Streaming + Streaming merges the GK summaries directly —
    /// the pooled rank error stays within ±εn of the *combined* count and
    /// no sample store is ever rehydrated, so multi-seed aggregation is
    /// O(1)-memory end to end in streaming mode. Mixed backends promote
    /// `self` to streaming first (feeding its stored samples through in
    /// stored order — deterministic), for the same reason.
    pub fn merge(&mut self, other: &TailDigest) {
        let mut promoted: Option<GkSketch> = None;
        match (&mut *self, other) {
            (TailDigest::Exact(a), TailDigest::Exact(b)) => a.merge(b),
            (TailDigest::Streaming(a), TailDigest::Streaming(b)) => a.merge(b),
            (TailDigest::Streaming(a), TailDigest::Exact(b)) => {
                for &v in &b.samples {
                    a.add(v);
                }
            }
            (TailDigest::Exact(a), TailDigest::Streaming(b)) => {
                let mut sk = GkSketch::with_epsilon(b.epsilon());
                for &v in &a.samples {
                    sk.add(v);
                }
                sk.merge(b);
                promoted = Some(sk);
            }
        }
        if let Some(sk) = promoted {
            *self = TailDigest::Streaming(sk);
        }
    }
}

/// Per-GPU-group busy/idle accounting for Eq. (1).
///
/// One `BusyTracker` tracks one replica (its GPUs move together). Busy
/// intervals accumulate via `set_busy`/`set_idle` transitions.
#[derive(Debug, Clone, Default)]
pub struct BusyTracker {
    busy_since: Option<f64>,
    pub busy_total: f64,
}

impl BusyTracker {
    pub fn set_busy(&mut self, now: f64) {
        if self.busy_since.is_none() {
            self.busy_since = Some(now);
        }
    }

    pub fn set_idle(&mut self, now: f64) {
        if let Some(t0) = self.busy_since.take() {
            debug_assert!(now >= t0 - 1e-9, "time moved backwards: {t0} -> {now}");
            self.busy_total += (now - t0).max(0.0);
        }
    }

    pub fn is_busy(&self) -> bool {
        self.busy_since.is_some()
    }

    /// Close any open interval at `end` and return total busy time.
    pub fn finish(&mut self, end: f64) -> f64 {
        self.set_idle(end);
        self.busy_total
    }
}

/// Eq. (1): GPU idle rate = sum(idle) / sum(exec + idle) over GPUs.
pub fn idle_rate(busy_times: &[f64], gpu_weights: &[usize], horizon: f64) -> f64 {
    assert_eq!(busy_times.len(), gpu_weights.len());
    if horizon <= 0.0 {
        return 0.0;
    }
    let mut busy = 0.0;
    let mut total = 0.0;
    for (b, &w) in busy_times.iter().zip(gpu_weights) {
        busy += b.min(horizon) * w as f64;
        total += horizon * w as f64;
    }
    if total <= 0.0 {
        return 0.0;
    }
    ((total - busy) / total).clamp(0.0, 1.0)
}

/// Everything one simulation run reports.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub policy: String,
    pub model: String,
    /// Queueing delay (arrival → prefill start) of short requests.
    pub short_queue_delay: TailDigest,
    /// Queueing delay of long requests.
    pub long_queue_delay: TailDigest,
    /// JCT (arrival → last token) of short requests.
    pub short_jct: TailDigest,
    /// JCT of long requests (only those that completed).
    pub long_jct: TailDigest,
    pub shorts_completed: usize,
    pub longs_completed: usize,
    pub longs_total: usize,
    /// Short requests shed at admission (terminal — counted, never run).
    pub shorts_shed: usize,
    /// Long requests shed at admission.
    pub longs_shed: usize,
    /// Requests that carried a completion deadline (the SLO population).
    pub deadlines_total: usize,
    /// Deadline-carrying requests that finished at or before it. Shed or
    /// unfinished deadline requests count as misses.
    pub deadlines_met: usize,
    /// Goodput numerator: completions that were useful under the SLO —
    /// finished with no deadline attached, or finished by their deadline.
    pub good_completions: usize,
    /// Long requests with no service by the time all shorts finished.
    pub longs_starved: usize,
    /// Total suspensions of long-request prefill (Tables 3/6) plus, under
    /// /CoL, suspensions of long-request decode.
    pub preemptions: u64,
    /// Makespan of the run, seconds (all tracked work complete).
    pub makespan: f64,
    /// Time the last short request completed (throughput window).
    pub t_shorts_done: f64,
    /// Eq. (1) idle rate over the run.
    pub gpu_idle_rate: f64,
    /// Misprediction regret (DESIGN.md §8): each short's queueing delay
    /// weighted by the configured predictor's capped relative length
    /// error on that request, summed in seconds. Isolates how much of
    /// the queueing the scheduler inflicted on requests it mis-sized —
    /// exactly 0.0 under the Oracle predictor.
    pub mispredict_regret: f64,
    /// Simulated events the engine processed — the event-volume regression
    /// signal for the decode epoch fast-forward (events per completion is
    /// O(1) between interruptions instead of O(output_len / decode_chunk)).
    pub events_processed: u64,
    /// Wall-clock scheduling time per request / simulated JCT (Table 7).
    /// Always exact `Digest`s: excluded from sweep JSON, tiny, and not
    /// worth a mode switch.
    pub sched_overhead_short: Digest,
    pub sched_overhead_long: Digest,
}

impl RunMetrics {
    /// Fresh metrics whose four tail digests use `mode`'s backend.
    pub fn with_mode(mode: MetricsMode) -> Self {
        Self {
            short_queue_delay: TailDigest::new(mode),
            long_queue_delay: TailDigest::new(mode),
            short_jct: TailDigest::new(mode),
            long_jct: TailDigest::new(mode),
            ..Self::default()
        }
    }

    /// Total stored entries across the four tail digests — the number the
    /// huge-sweep smoke asserts is trace-length independent in streaming
    /// mode (samples in exact mode, sketch tuples in streaming mode).
    pub fn metric_entries(&self) -> usize {
        self.short_queue_delay.entries()
            + self.long_queue_delay.entries()
            + self.short_jct.entries()
            + self.long_jct.entries()
    }

    /// Throughput of short requests (Fig. 2b/3b/10), requests per second,
    /// measured over the window in which the short workload was served
    /// (so a policy that merely delays *long* completions is not
    /// penalised, and one that delays shorts is).
    pub fn short_rps(&self) -> f64 {
        let window = if self.t_shorts_done > 0.0 {
            self.t_shorts_done
        } else {
            self.makespan
        };
        if window <= 0.0 {
            return 0.0;
        }
        self.shorts_completed as f64 / window
    }

    pub fn starved_frac(&self) -> f64 {
        if self.longs_total == 0 {
            return 0.0;
        }
        self.longs_starved as f64 / self.longs_total as f64
    }

    /// Deterministic scalar digest of this run: only simulated-time
    /// quantities — the wall-clock scheduling-overhead digests are
    /// deliberately excluded — so sweep output built from summaries is
    /// byte-identical across thread counts and machine load (and across
    /// hosts in practice, modulo per-platform libm ULP differences).
    /// Empty digests zero-fill their summary fields (the documented
    /// serialization of "no samples").
    pub fn summary(&mut self) -> RunSummary {
        RunSummary {
            short_delay_pcts: self.short_queue_delay.paper_percentiles().unwrap_or([0.0; 5]),
            short_rps: self.short_rps(),
            long_jct_mean: self.long_jct.mean().unwrap_or(0.0),
            shorts_completed: self.shorts_completed,
            longs_completed: self.longs_completed,
            longs_total: self.longs_total,
            shorts_shed: self.shorts_shed,
            longs_shed: self.longs_shed,
            deadlines_total: self.deadlines_total,
            deadlines_met: self.deadlines_met,
            good_completions: self.good_completions,
            longs_starved: self.longs_starved,
            preemptions: self.preemptions,
            gpu_idle_rate: self.gpu_idle_rate,
            mispredict_regret: self.mispredict_regret,
            makespan: self.makespan,
            events_processed: self.events_processed,
        }
    }
}

/// The deterministic per-run digest [`RunMetrics::summary`] produces —
/// the unit of cross-seed aggregation and the sweep JSON.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunSummary {
    /// Short-request queueing-delay percentiles (p1, p25, p50, p75, p99);
    /// zeros when the run served no shorts.
    pub short_delay_pcts: [f64; 5],
    pub short_rps: f64,
    pub long_jct_mean: f64,
    pub shorts_completed: usize,
    pub longs_completed: usize,
    pub longs_total: usize,
    /// Requests shed at admission (terminal, counted — never silently
    /// dropped): conservation is `completed + shed == arrived`.
    pub shorts_shed: usize,
    pub longs_shed: usize,
    /// Requests that carried a completion deadline.
    pub deadlines_total: usize,
    /// Deadline-carrying requests that finished at or before it.
    pub deadlines_met: usize,
    /// Completions useful under the SLO (no deadline, or deadline met).
    pub good_completions: usize,
    pub longs_starved: usize,
    pub preemptions: u64,
    pub gpu_idle_rate: f64,
    /// Misprediction regret, seconds (see
    /// [`RunMetrics::mispredict_regret`]).
    pub mispredict_regret: f64,
    pub makespan: f64,
    pub events_processed: u64,
}

impl RunSummary {
    pub fn short_p99_delay(&self) -> f64 {
        self.short_delay_pcts[4]
    }

    /// Mirror of [`RunMetrics::starved_frac`] on the summary type.
    pub fn starved_frac(&self) -> f64 {
        if self.longs_total == 0 {
            return 0.0;
        }
        self.longs_starved as f64 / self.longs_total as f64
    }

    /// SLO attainment: fraction of deadline-carrying requests that
    /// finished by their deadline. Vacuously 1.0 when nothing carried a
    /// deadline (there was no SLO to miss).
    pub fn slo_attainment(&self) -> f64 {
        if self.deadlines_total == 0 {
            return 1.0;
        }
        self.deadlines_met as f64 / self.deadlines_total as f64
    }

    /// Goodput: SLO-useful completions per second of makespan. Equals
    /// total completion throughput when no request carries a deadline.
    pub fn goodput_rps(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.good_completions as f64 / self.makespan
    }

    /// Fraction of arrived requests shed at admission.
    pub fn shed_frac(&self) -> f64 {
        let shed = self.shorts_shed + self.longs_shed;
        let arrived = self.shorts_completed + self.longs_completed + shed;
        if arrived == 0 {
            return 0.0;
        }
        shed as f64 / arrived as f64
    }
}

/// Cross-seed aggregate of one sweep group: per-metric means plus the
/// min/max spread of the p99 short queueing delay across seeds — the
/// "does the headline tail survive a different arrival draw" signal.
#[derive(Debug, Clone, Default)]
pub struct SeedAggregate {
    pub seeds: usize,
    pub short_p99_delay_mean: f64,
    pub short_p99_delay_min: f64,
    pub short_p99_delay_max: f64,
    pub short_rps_mean: f64,
    pub long_jct_mean: f64,
    pub preemptions_mean: f64,
    pub gpu_idle_rate_mean: f64,
    /// Mean SLO attainment across seeds (1.0 when no deadlines anywhere).
    pub slo_attainment_mean: f64,
    /// Mean goodput (SLO-useful completions / second) across seeds.
    pub goodput_rps_mean: f64,
    /// Mean fraction of arrivals shed at admission across seeds.
    pub shed_frac_mean: f64,
    /// Mean misprediction regret (seconds) across seeds.
    pub mispredict_regret_mean: f64,
}

/// Aggregate one group of per-seed summaries (all from the same
/// model × policy × scenario × load cell).
pub fn aggregate_seeds(runs: &[RunSummary]) -> SeedAggregate {
    assert!(!runs.is_empty(), "aggregate of zero runs");
    let n = runs.len() as f64;
    let mean = |f: &dyn Fn(&RunSummary) -> f64| runs.iter().map(|r| f(r)).sum::<f64>() / n;
    let p99s: Vec<f64> = runs.iter().map(|r| r.short_p99_delay()).collect();
    SeedAggregate {
        seeds: runs.len(),
        short_p99_delay_mean: mean(&|r| r.short_p99_delay()),
        short_p99_delay_min: p99s.iter().copied().fold(f64::INFINITY, f64::min),
        short_p99_delay_max: p99s.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        short_rps_mean: mean(&|r| r.short_rps),
        long_jct_mean: mean(&|r| r.long_jct_mean),
        preemptions_mean: mean(&|r| r.preemptions as f64),
        gpu_idle_rate_mean: mean(&|r| r.gpu_idle_rate),
        slo_attainment_mean: mean(&|r| r.slo_attainment()),
        goodput_rps_mean: mean(&|r| r.goodput_rps()),
        shed_frac_mean: mean(&|r| r.shed_frac()),
        mispredict_regret_mean: mean(&|r| r.mispredict_regret),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_quantiles_exact_on_uniform() {
        let mut d = Digest::new();
        for i in 0..=100 {
            d.add(i as f64);
        }
        assert_eq!(d.quantile(0.0), Some(0.0));
        assert_eq!(d.quantile(0.5), Some(50.0));
        assert_eq!(d.quantile(1.0), Some(100.0));
        assert!((d.quantile(0.99).unwrap() - 99.0).abs() < 1e-9);
    }

    #[test]
    fn digest_interpolates() {
        let mut d = Digest::new();
        d.add(0.0);
        d.add(10.0);
        assert!((d.quantile(0.25).unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn digest_single_sample() {
        let mut d = Digest::new();
        d.add(7.0);
        assert_eq!(d.quantile(0.99), Some(7.0));
        assert_eq!(d.mean(), Some(7.0));
        assert_eq!(d.max(), Some(7.0));
    }

    #[test]
    fn empty_digest_queries_are_uniformly_none() {
        // Satellite fix: quantile used to panic while mean returned 0.0 —
        // every query on an empty digest now answers None.
        let mut d = Digest::new();
        assert_eq!(d.quantile(0.5), None);
        assert_eq!(d.mean(), None);
        assert_eq!(d.max(), None);
        assert_eq!(d.paper_percentiles(), None);
        let mut t = TailDigest::new(MetricsMode::Streaming);
        assert_eq!(t.quantile(0.5), None);
        assert_eq!(t.mean(), None);
        assert_eq!(t.max(), None);
        assert_eq!(t.paper_percentiles(), None);
    }

    #[test]
    fn digest_max_is_running_not_sorted() {
        // Satellite fix: max no longer sorts — it must be correct even
        // while the sample vec is unsorted, and O(1) to read.
        let mut d = Digest::new();
        for v in [3.0, 9.0, 1.0, 7.5] {
            d.add(v);
        }
        assert_eq!(d.max(), Some(9.0));
        // Interleave with a sort-forcing quantile and keep adding.
        assert!(d.quantile(0.5).is_some());
        d.add(11.0);
        d.add(2.0);
        assert_eq!(d.max(), Some(11.0));
    }

    #[test]
    fn tail_digest_streaming_matches_exact_on_count_mean_max() {
        let mut ex = TailDigest::new(MetricsMode::Exact);
        let mut st = TailDigest::new(MetricsMode::Streaming);
        for i in 0..10_000 {
            let v = ((i * 7919) % 1000) as f64 / 10.0;
            ex.add(v);
            st.add(v);
        }
        assert_eq!(ex.len(), st.len());
        assert!((ex.mean().unwrap() - st.mean().unwrap()).abs() < 1e-9);
        assert_eq!(ex.max(), st.max());
        // The streaming backend is the whole point: bounded entries.
        assert!(st.entries() < ex.entries());
    }

    #[test]
    fn tail_digest_merge_pools_across_backends() {
        // Exact+Exact pools exactly.
        let mut a = TailDigest::new(MetricsMode::Exact);
        let mut b = TailDigest::new(MetricsMode::Exact);
        for i in 0..50 {
            a.add(i as f64);
        }
        for i in 50..100 {
            b.add(i as f64);
        }
        a.merge(&b);
        assert_eq!(a.len(), 100);
        assert_eq!(a.max(), Some(99.0));
        assert!((a.quantile(0.5).unwrap() - 49.5).abs() < 1e-9);
        assert!(matches!(a, TailDigest::Exact(_)));

        // Exact+Streaming promotes to streaming — pooling never
        // rehydrates an exact store (count/mean/max stay exact).
        let mut ex = TailDigest::new(MetricsMode::Exact);
        let mut st = TailDigest::new(MetricsMode::Streaming);
        for i in 0..2_000 {
            ex.add(i as f64);
            st.add((2_000 + i) as f64);
        }
        ex.merge(&st);
        assert!(matches!(ex, TailDigest::Streaming(_)));
        assert_eq!(ex.len(), 4_000);
        assert_eq!(ex.max(), Some(3_999.0));
        let med = ex.quantile(0.5).unwrap();
        assert!((med - 2_000.0).abs() < 50.0, "pooled median {med}");

        // Streaming+Exact feeds the samples through.
        let mut s2 = TailDigest::new(MetricsMode::Streaming);
        s2.add(1.0);
        let mut e2 = TailDigest::new(MetricsMode::Exact);
        e2.add(2.0);
        s2.merge(&e2);
        assert_eq!(s2.len(), 2);
        assert_eq!(s2.max(), Some(2.0));
    }

    #[test]
    fn slo_and_goodput_helpers() {
        let s = RunSummary {
            deadlines_total: 8,
            deadlines_met: 6,
            good_completions: 30,
            shorts_completed: 28,
            longs_completed: 4,
            shorts_shed: 8,
            longs_shed: 0,
            makespan: 10.0,
            ..Default::default()
        };
        assert!((s.slo_attainment() - 0.75).abs() < 1e-12);
        assert!((s.goodput_rps() - 3.0).abs() < 1e-12);
        assert!((s.shed_frac() - 0.2).abs() < 1e-12);
        // No deadlines anywhere: vacuously attained, goodput == rps.
        let none = RunSummary::default();
        assert_eq!(none.slo_attainment(), 1.0);
        assert_eq!(none.goodput_rps(), 0.0);
        assert_eq!(none.shed_frac(), 0.0);
    }

    #[test]
    fn busy_tracker_accumulates() {
        let mut b = BusyTracker::default();
        b.set_busy(1.0);
        b.set_busy(2.0); // no-op, already busy
        b.set_idle(4.0);
        b.set_idle(5.0); // no-op
        b.set_busy(10.0);
        assert_eq!(b.finish(12.0), 5.0);
    }

    #[test]
    fn idle_rate_eq1() {
        // Two single-GPU replicas, one busy the whole horizon, one never.
        assert!((idle_rate(&[10.0, 0.0], &[1, 1], 10.0) - 0.5).abs() < 1e-12);
        // GPU weighting: a TP=4 idle replica dominates a TP=1 busy one.
        let r = idle_rate(&[10.0, 0.0], &[1, 4], 10.0);
        assert!((r - 0.8).abs() < 1e-12);
        assert_eq!(idle_rate(&[], &[], 10.0), 0.0);
    }

    #[test]
    fn idle_rate_clamps_busy_beyond_horizon() {
        // busy > horizon (a replica whose last interval closed after the
        // chosen horizon): the min(horizon) clamp keeps the rate at 0,
        // never negative.
        assert_eq!(idle_rate(&[15.0], &[1], 10.0), 0.0);
        // Mixed: the over-busy replica contributes exactly `horizon` busy.
        let r = idle_rate(&[15.0, 0.0], &[1, 1], 10.0);
        assert!((r - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rps_and_starvation() {
        let m = RunMetrics {
            shorts_completed: 50,
            makespan: 10.0,
            longs_total: 4,
            longs_starved: 3,
            ..Default::default()
        };
        assert!((m.short_rps() - 5.0).abs() < 1e-12);
        assert!((m.starved_frac() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn summary_is_deterministic_and_skips_wall_clock() {
        let mut m = RunMetrics {
            shorts_completed: 10,
            makespan: 5.0,
            longs_total: 2,
            longs_completed: 2,
            preemptions: 3,
            gpu_idle_rate: 0.25,
            events_processed: 99,
            ..Default::default()
        };
        m.short_queue_delay.add(1.0);
        m.short_queue_delay.add(3.0);
        m.long_jct.add(10.0);
        // Wall-clock overhead present but absent from the summary type.
        m.sched_overhead_short.add(0.123);
        let s = m.summary();
        assert_eq!(s, m.summary());
        assert_eq!(
            Some(s.short_p99_delay()),
            m.short_queue_delay.quantile(0.99)
        );
        assert_eq!(s.preemptions, 3);
        assert_eq!(s.events_processed, 99);
        assert!((s.long_jct_mean - 10.0).abs() < 1e-12);
    }

    #[test]
    fn summary_zero_fills_empty_digests() {
        let mut m = RunMetrics::with_mode(MetricsMode::Streaming);
        let s = m.summary();
        assert_eq!(s.short_delay_pcts, [0.0; 5]);
        assert_eq!(s.long_jct_mean, 0.0);
    }

    #[test]
    fn aggregate_seeds_mean_and_spread() {
        let mk = |p99: f64, rps: f64| RunSummary {
            short_delay_pcts: [0.0, 0.0, 0.0, 0.0, p99],
            short_rps: rps,
            long_jct_mean: 100.0,
            preemptions: 4,
            gpu_idle_rate: 0.5,
            mispredict_regret: rps / 10.0,
            ..Default::default()
        };
        let a = aggregate_seeds(&[mk(1.0, 10.0), mk(3.0, 20.0)]);
        assert_eq!(a.seeds, 2);
        assert!((a.short_p99_delay_mean - 2.0).abs() < 1e-12);
        assert_eq!(a.short_p99_delay_min, 1.0);
        assert_eq!(a.short_p99_delay_max, 3.0);
        assert!((a.short_rps_mean - 15.0).abs() < 1e-12);
        assert!((a.preemptions_mean - 4.0).abs() < 1e-12);
        assert!((a.mispredict_regret_mean - 1.5).abs() < 1e-12);
    }

    #[test]
    fn aggregate_seeds_single_seed_group() {
        // Satellite coverage: a one-seed group must report mean == min ==
        // max (the spread collapses to the single observation).
        let one = RunSummary {
            short_delay_pcts: [0.1, 0.2, 0.3, 0.4, 2.5],
            short_rps: 12.0,
            long_jct_mean: 80.0,
            preemptions: 7,
            gpu_idle_rate: 0.3,
            ..Default::default()
        };
        let a = aggregate_seeds(&[one]);
        assert_eq!(a.seeds, 1);
        assert_eq!(a.short_p99_delay_mean, 2.5);
        assert_eq!(a.short_p99_delay_min, 2.5);
        assert_eq!(a.short_p99_delay_max, 2.5);
        assert_eq!(a.short_rps_mean, 12.0);
        assert_eq!(a.long_jct_mean, 80.0);
        assert_eq!(a.preemptions_mean, 7.0);
        assert_eq!(a.gpu_idle_rate_mean, 0.3);
    }

    #[test]
    fn paper_percentiles_ordering() {
        let mut d = Digest::new();
        for i in 0..1000 {
            d.add((i % 37) as f64);
        }
        let p = d.paper_percentiles().unwrap();
        for w in p.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
}
