//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This fully-vendored build has no registry access (DESIGN.md §2
//! documents the substitution policy), so the subset of `anyhow` the
//! codebase actually uses is reimplemented here: `Error`, `Result`,
//! the `Context` extension trait on `Result`/`Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error values carry a flat,
//! already-formatted message (context frames are prepended as
//! `"{context}: {cause}"`), which is all the binaries and tests print.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, so the blanket `From<E: std::error::Error>`
//! conversion used by `?` cannot collide with the reflexive
//! `From<Error>` impl.

use std::fmt;

/// Flat-message error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro's
    /// entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }

    fn wrap<C: fmt::Display>(self, ctx: C) -> Self {
        Self {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(...)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] from format-string arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i64> {
        let v: i64 = s.parse().context("not an integer")?;
        ensure!(v >= 0, "negative: {v}");
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors_with_context() {
        assert_eq!(parse("42").unwrap(), 42);
        let e = parse("x").unwrap_err();
        assert!(e.to_string().starts_with("not an integer: "));
        assert_eq!(parse("-1").unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let e = anyhow!("bad {}", 7);
        assert_eq!(format!("{e}"), "bad 7");
        assert_eq!(format!("{e:?}"), "bad 7");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let mut called = false;
        let r: Result<u8> = "3".parse::<u8>().with_context(|| {
            called = true;
            "not evaluated on Ok"
        });
        assert_eq!(r.unwrap(), 3);
        assert!(!called, "with_context closure ran on Ok");
    }
}
