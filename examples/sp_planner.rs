//! Fast-SP planner demo: §5.3's hybrid strategy selection across sequence
//! lengths and replica counts for one model, including the per-stage
//! comm/comp breakdown the selector reasons over.
//!
//! Run: `cargo run --release --example sp_planner -- --model phi-3-14b`

use pecsched::config::ModelSpec;
use pecsched::costmodel::{sp, CostModel, SpChoice, SpStage};
use pecsched::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model_name = args.str_or("model", "phi-3-14b");
    let model = ModelSpec::by_name(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;
    let cm = CostModel::new(model.clone(), Default::default());

    println!("=== {} (TP={}) — stage cost breakdown ===", model.name, model.tp);
    for &len in &[100_000u32, 300_000, 500_000] {
        let n = cm.replicas_for_long(len, 131_072);
        let seg = len as f64 / (n * model.tp) as f64;
        println!("\ninput {len} tokens over {n} replicas (segment/GPU = {seg:.0}):");
        for stage in [SpStage::Attention, SpStage::Mlp] {
            for choice in [SpChoice::Megatron, SpChoice::Ulysses] {
                let c = sp::stage_cost(&cm, stage, choice, seg, 8);
                println!(
                    "  {:?}/{:?}: comm={:.2}ms comp={:.2}ms per layer",
                    stage,
                    choice,
                    c.comm_s * 1e3,
                    c.comp_s * 1e3
                );
            }
        }
        let fast = sp::plan_fast_sp(&cm, len, n, 8);
        let ring = sp::plan_ring_only(&cm, len, n, 8);
        println!(
            "  -> plan: attn={:?} mlp={:?}; fast {:.1}s vs ring-only {:.1}s \
             ({:.2}x speedup)",
            fast.attn,
            fast.mlp,
            fast.total_time(&cm, len),
            ring.total_time(&cm, len),
            ring.total_time(&cm, len) / fast.total_time(&cm, len)
        );
    }
    Ok(())
}
