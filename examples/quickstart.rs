//! Quickstart: generate a small Azure-shape workload, run the cluster
//! simulator under FIFO and PecSched, and print the comparison the paper
//! leads with — short-request queueing delay and long-request JCT.
//!
//! Run: `cargo run --release --example quickstart`

use pecsched::config::{AblationFlags, ModelSpec, PolicyKind};
use pecsched::exp::{capacity_rps, fmt_pcts, EXP_LONG_QUANTILE};
use pecsched::sim::{run_sim, SimConfig};
use pecsched::trace::TraceConfig;

fn main() {
    let model = ModelSpec::mistral_7b();
    let trace = TraceConfig {
        n_requests: 5_000,
        rps: capacity_rps(&model, 0.7),
        long_quantile: EXP_LONG_QUANTILE,
        seed: 1,
        ..TraceConfig::default()
    }
    .generate();
    println!(
        "workload: {} requests ({} long), {:.0}s arrival window",
        trace.len(),
        trace.longs().count(),
        trace.span()
    );

    // The full lineup is registered in `PolicyKind::all()` (see
    // `pecsched list-policies`); SJF rides along here as the policy
    // written purely against the ClusterView/ClusterOps API.
    for kind in [
        PolicyKind::Fifo,
        PolicyKind::Sjf,
        PolicyKind::PecSched(AblationFlags::full()),
    ] {
        let cfg = SimConfig::for_policy(model.clone(), kind);
        let mut m = run_sim(cfg, &trace, kind);
        println!("\n--- {} ---", m.policy);
        println!(
            "{}",
            fmt_pcts(
                "short delay",
                m.short_queue_delay.paper_percentiles().unwrap_or([0.0; 5])
            )
        );
        println!("short throughput : {:.2} RPS", m.short_rps());
        println!(
            "long avg JCT     : {:.1}s",
            m.long_jct.mean().unwrap_or(0.0)
        );
        println!("preemptions      : {}", m.preemptions);
    }
    println!(
        "\nPecSched keeps short-request latency near zero by letting short \
         prefills preempt long prefills, while long JCT stays within a few \
         percent of FIFO (§6.3)."
    );
}
