//! Full §6.3-style cluster simulation on one model: all four policies,
//! CSV output for plotting.
//!
//! Run: `cargo run --release --example cluster_sim -- --model yi-34b \
//!       --requests 20000 --out results.csv`

use std::fmt::Write as _;

use pecsched::config::{ModelSpec, PolicyKind};
use pecsched::exp::{run_cell, trace_for, ExpParams};
use pecsched::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model_name = args.str_or("model", "yi-34b");
    let model = ModelSpec::by_name(&model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model_name}"))?;
    let p = ExpParams {
        n_requests: args.parse_or("requests", 20_000usize)?,
        seed: args.parse_or("seed", 42u64)?,
        load: args.parse_or("load", 0.7f64)?,
    };
    let trace = trace_for(&model, &p);
    eprintln!(
        "model={} requests={} longs={} window={:.0}s",
        model.name,
        trace.len(),
        trace.longs().count(),
        trace.span()
    );

    let mut csv = String::from(
        "policy,p1,p25,p50,p75,p99,short_rps,long_jct_mean,preemptions,\
         idle_rate,starved_frac\n",
    );
    for kind in PolicyKind::comparison_set() {
        let mut m = run_cell(&model, kind, &trace);
        let d = m.short_queue_delay.paper_percentiles().unwrap_or([f64::NAN; 5]);
        writeln!(
            csv,
            "{},{:.4},{:.4},{:.4},{:.4},{:.4},{:.3},{:.1},{},{:.4},{:.3}",
            m.policy,
            d[0],
            d[1],
            d[2],
            d[3],
            d[4],
            m.short_rps(),
            m.long_jct.mean().unwrap_or(f64::NAN),
            m.preemptions,
            m.gpu_idle_rate,
            m.starved_frac()
        )?;
        eprintln!("{} done", m.policy);
    }

    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &csv)?;
            eprintln!("wrote {path}");
        }
        None => print!("{csv}"),
    }
    Ok(())
}
