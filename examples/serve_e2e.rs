//! End-to-end serving driver (DESIGN.md §6): load the real AOT-compiled
//! model through PJRT, serve a mixed short/long workload through the rust
//! engine in both FIFO and PecSched modes, and report TTFT percentiles,
//! queueing delay and throughput — the single-host incarnation of the
//! paper's headline comparison, on *real* execution (L1 Pallas kernels
//! inside L2 HLO driven by the L3 coordinator).
//!
//! Run after `make artifacts`:
//!   `cargo run --release --example serve_e2e`

use std::time::Instant;

use pecsched::runtime::Artifacts;
use pecsched::server::{EngineConfig, EngineMode, ServeRequest, ServerHandle};
use pecsched::util::Rng;

struct WorkloadResult {
    ttfts_short: Vec<f64>,
    queue_short: Vec<f64>,
    wall_s: f64,
    completed: usize,
    preemptions: u64,
}

fn run_mode(mode: EngineMode, n: usize, seed: u64) -> anyhow::Result<WorkloadResult> {
    let dir = Artifacts::default_dir();
    anyhow::ensure!(
        Artifacts::available(&dir),
        "artifacts missing — run `make artifacts` first"
    );
    let cfg = EngineConfig {
        mode,
        long_prompt_threshold: 192,
        ..EngineConfig::default()
    };
    let handle = ServerHandle::start(&dir, cfg)?;

    // Mixed workload: mostly short prompts, every 10th request a "long"
    // prompt (chunk-prefilled, preemptible). Deterministic via seed.
    let mut rng = Rng::seed_from_u64(seed);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    let mut is_long = Vec::new();
    for i in 0..n {
        let long = i % 10 == 9;
        let plen = if long {
            256 + rng.below(128)
        } else {
            8 + rng.below(48)
        };
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.below(2000) as i32 + 1).collect();
        is_long.push(long);
        rxs.push(handle.submit(ServeRequest {
            id: i as u64,
            prompt,
            max_new_tokens: 6,
        }));
    }

    let mut ttfts_short = Vec::new();
    let mut queue_short = Vec::new();
    let mut completed = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv()?;
        completed += 1;
        if !is_long[i] {
            ttfts_short.push(r.ttft_s);
            queue_short.push(r.queue_s);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let stats = handle.shutdown()?;
    Ok(WorkloadResult {
        ttfts_short,
        queue_short,
        wall_s,
        completed,
        preemptions: stats.preemptions,
    })
}

fn pct(xs: &mut [f64], q: f64) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[((xs.len() - 1) as f64 * q) as usize]
}

fn main() -> anyhow::Result<()> {
    let n = std::env::var("SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(60usize);
    println!("serving {n} requests per mode on the PJRT CPU engine...\n");

    let mut rows = Vec::new();
    for (name, mode) in [("FIFO", EngineMode::Fifo), ("PecSched", EngineMode::PecSched)] {
        let mut r = run_mode(mode, n, 7)?;
        let p50 = pct(&mut r.ttfts_short, 0.5);
        let p99 = pct(&mut r.ttfts_short, 0.99);
        let q99 = pct(&mut r.queue_short, 0.99);
        println!(
            "{name:<9} completed={:<4} wall={:.2}s throughput={:.2} req/s\n\
             {:<9} short TTFT p50={:.3}s p99={:.3}s; short queue p99={:.3}s; \
             preemptions={}",
            r.completed,
            r.wall_s,
            r.completed as f64 / r.wall_s,
            "",
            p50,
            p99,
            q99,
            r.preemptions
        );
        rows.push((name, p99));
    }

    let (_, fifo_p99) = rows[0];
    let (_, pec_p99) = rows[1];
    println!(
        "\nshort-request TTFT p99: PecSched {:.3}s vs FIFO {:.3}s \
         ({:.0}% reduction) — the paper's head-of-line-blocking fix, \
         reproduced on real execution.",
        pec_p99,
        fifo_p99,
        (1.0 - pec_p99 / fifo_p99) * 100.0
    );
    Ok(())
}
